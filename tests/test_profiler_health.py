"""Conservation ledger, span profiler, health engine, SSD re-probe.

Acceptance properties (docs/OBSERVABILITY.md):

* the :class:`TimeLedger` conserves: category sums reproduce the
  horizon (time) and the accountant's operational total (gCO2) within
  tolerance; negative charges refuse; the streamed ``ledger`` counter
  samples reconstruct the same ledger from a trace file alone;
* the span profiler nests spans by time containment with exact
  self/total accounting, emits valid collapsed-stack lines, aggregates
  dispatch groups and ranks the hottest requests;
* the health engine evaluates value/rate/ratio/quantile rules on
  modeled-clock snapshots, honors ``for_s`` holds, records
  firing/resolved transitions, skips unreported metrics, and
  round-trips rule files and alert JSONL;
* a traced + ledgered serving run balances to ~0 residue without
  perturbing the modeled clock, and ``scripts/perf_report.py`` rebuilds
  ledger + profile + alerts from the exported trace;
* a quarantined SSD tier re-probes on the modeled clock with bounded
  exponential backoff and rejoins on success (no restart needed) —
  and stays quarantined forever without a clock, the pre-probe
  behavior.
"""
import json
import pathlib
import sys

import pytest

from repro.core.engine import M2CacheEngine
from repro.obs import (AlertRule, HealthMonitor, MetricsRegistry,
                       TimeLedger, TraceRecorder, alerts_from_events,
                       build_tree, collapsed_stacks, default_rules,
                       dispatch_groups, events_from_chrome,
                       events_from_recorder, hottest_requests, load_rules,
                       profile_summary, reconstruct)
from repro.serving import ContinuousBatchScheduler, requests_from_trace
from repro.serving.faults import FaultInjector
from repro.serving.kv_cache import TieredKVCache
from repro.serving.workload import ArrivalEvent

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                       / "scripts"))
import perf_report  # noqa: E402


# ---------------------------------------------------------------------------
# TimeLedger


def test_ledger_billing_and_conservation():
    led = TimeLedger()
    led.bill("prefill_compute/b4", 2.0)
    led.bill("prefill_compute/b8", 1.0)
    led.bill("weight_stall", 6.5)
    led.bill("idle", 0.5)
    led.bill_g("weight_stall", 0.9)
    led.bill_g("idle", 0.1)
    led.close(span_s=10.0, gco2_total_g=1.0)
    assert led.time_total() == pytest.approx(10.0)
    assert led.by_family()["prefill_compute"] == pytest.approx(3.0)
    assert led.check() == []                 # conserves exactly
    res = led.residues()
    assert res["time_residue_frac"] == pytest.approx(0.0)
    assert res["gco2_residue_frac"] == pytest.approx(0.0)
    assert led.summary()["conserved"]


def test_ledger_detects_unbilled_and_negative_charges():
    led = TimeLedger()
    led.bill("decode_compute/b1", 5.0)
    led.close(span_s=10.0)                   # 50% of the horizon missing
    errs = led.check()
    assert len(errs) == 1 and "time residue" in errs[0]
    assert not led.summary()["conserved"]
    with pytest.raises(ValueError, match="negative"):
        led.bill("idle", -0.1)
    with pytest.raises(ValueError, match="negative"):
        led.bill_g("idle", -0.1)
    # an unclosed ledger refuses to claim conservation
    assert "not closed" in TimeLedger().check()[0]


def test_ledger_horizon_extends_past_span():
    led = TimeLedger()
    led.bill("decode_compute/b2", 4.0)
    led.bill("trailing_idle", 6.0)
    led.close(span_s=4.0, horizon_s=10.0)
    assert led.horizon_s == 10.0
    assert led.check() == []
    # a horizon shorter than the span is clamped to the span
    led2 = TimeLedger()
    led2.bill("idle", 3.0)
    led2.close(span_s=3.0, horizon_s=1.0)
    assert led2.horizon_s == 3.0


def test_ledger_trace_roundtrip_and_export(tmp_path):
    led = TimeLedger()
    led.bill("prefill_compute/b4", 1.25)
    led.bill("kv_stall", 0.75)
    led.bill_g("kv_stall", 0.5)
    led.close(span_s=2.0, gco2_total_g=0.5, embodied_g=0.1)
    tr = TraceRecorder()
    led.emit(tr, 1.0)
    led.emit(tr, 2.0)                        # cumulative: last sample wins
    back = reconstruct(events_from_recorder(tr))
    assert back.time_s == pytest.approx(led.time_s)
    assert back.gco2_g == pytest.approx(led.gco2_g)
    assert back.span_s == led.span_s
    assert back.gco2_total_g == led.gco2_total_g
    assert back.check() == []
    # and through a Chrome export file (the perf_report path)
    path = tmp_path / "l.trace.json"
    tr.export_chrome(str(path))
    back2 = reconstruct(events_from_chrome(json.loads(path.read_text())))
    assert back2.time_total() == pytest.approx(led.time_total())
    led.export(str(tmp_path / "l.ledger.json"))
    doc = json.loads((tmp_path / "l.ledger.json").read_text())
    assert doc["conserved"] and doc["time_s"]["kv_stall"] == 0.75


# ---------------------------------------------------------------------------
# span profiler


def test_tree_self_total_nesting():
    tr = TraceRecorder()
    tr.span("sched", "outer", 0.0, 10.0)
    tr.span("sched", "inner", 1.0, 4.0)      # child of outer
    tr.span("sched", "inner", 5.0, 7.0)      # second call
    tr.span("sched", "leaf", 2.0, 3.0)       # grandchild under inner #1
    tree = build_tree(events_from_recorder(tr))
    outer = tree["sched"]["children"]["outer"]
    inner = outer["children"]["inner"]
    leaf = inner["children"]["leaf"]
    assert outer["total_s"] == pytest.approx(10.0)
    assert inner["total_s"] == pytest.approx(5.0) and inner["count"] == 2
    assert leaf["total_s"] == pytest.approx(1.0)
    assert inner["self_s"] == pytest.approx(4.0)
    assert outer["self_s"] == pytest.approx(5.0)
    lines = collapsed_stacks(tree)
    assert "sched;outer 5000000" in lines
    assert "sched;outer;inner;leaf 1000000" in lines
    # every line is "frames <int-us>"
    for ln in lines:
        stack, us = ln.rsplit(" ", 1)
        assert int(us) > 0 and stack.startswith("sched;")


def test_dispatch_groups_and_hottest_requests():
    tr = TraceRecorder()
    for t in (0.0, 1.0):
        tr.span("engine", "dispatch", t, t + 0.5, phase="decode", batch=2,
                compute_s=0.1, hbm_load_s=0.0, hbm_read_s=0.2,
                kernel_launch_s=0.01, stall_s=0.19)
    tr.span("engine", "dispatch", 2.0, 2.4, phase="prefill", batch=8,
            compute_s=0.3, hbm_load_s=0.0, hbm_read_s=0.05,
            kernel_launch_s=0.01, stall_s=0.0)
    tr.span("req:0", "queued", 0.0, 1.0)
    tr.span("req:0", "decode", 1.0, 4.0)
    tr.span("req:1", "prefill", 0.0, 2.0)
    tr.span("req:1", "preempted", 2.0, 2.5)
    evs = events_from_recorder(tr)
    g = dispatch_groups(evs)
    assert g["decode/b2"]["dispatches"] == 2
    assert g["decode/b2"]["hbm_read_s"] == pytest.approx(0.4)
    assert g["prefill/b8"]["total_s"] == pytest.approx(0.4)
    hot = hottest_requests(evs, n=1)
    assert hot[0]["rid"] == "0" and hot[0]["busy_s"] == pytest.approx(3.0)
    both = hottest_requests(evs, n=5)
    assert [r["rid"] for r in both] == ["0", "1"]
    assert both[1]["parked_s"] == pytest.approx(0.5)
    prof = profile_summary(evs, top=1)
    assert prof["tracks"]["engine"]["spans"] == 3
    assert len(prof["hottest_requests"]) == 1


# ---------------------------------------------------------------------------
# health / alert rules


def _tick(reg, **vals):
    for name, v in vals.items():
        reg.counter(name).inc(v)


def test_alert_rule_validation_and_files(tmp_path):
    with pytest.raises(ValueError, match="unknown op"):
        AlertRule("x", "m", op="!=")
    with pytest.raises(ValueError, match="unknown mode"):
        AlertRule("x", "m", mode="median")
    with pytest.raises(ValueError, match="denominator"):
        AlertRule("x", "m", mode="ratio")
    with pytest.raises(ValueError, match="unknown fields"):
        AlertRule.from_dict({"name": "x", "metric": "m", "severty": "oops"})
    rules = default_rules()
    assert {r.name for r in rules} >= {
        "slo_burn", "ttft_p95_high", "ssd_quarantine", "recovery_rate",
        "failure_rate", "dram_overcommit", "prefix_hit_collapse",
        "trace_ring_drops", "snapshot_drops"}
    path = tmp_path / "rules.json"
    path.write_text(json.dumps({"rules": [r.to_dict() for r in rules]}))
    assert [r.name for r in load_rules(str(path))] == \
        [r.name for r in rules]
    # a bare list loads too
    path.write_text(json.dumps([rules[0].to_dict()]))
    assert load_rules(str(path))[0].name == rules[0].name


def test_health_value_rule_fires_and_resolves():
    reg = MetricsRegistry()
    g = reg.gauge("pressure")
    hm = HealthMonitor(reg, [AlertRule("hot", "pressure", op=">",
                                       threshold=5.0)])
    assert hm.evaluate(0.0) == []            # unset gauge: rule skipped
    g.set(3.0)
    assert hm.evaluate(1.0) == []
    g.set(9.0)
    new = hm.evaluate(2.0)
    assert [a["state"] for a in new] == ["firing"]
    assert hm.active() == ["hot"] and hm.fired("hot")
    assert hm.evaluate(3.0) == []            # still firing: no re-record
    g.set(1.0)
    assert [a["state"] for a in hm.evaluate(4.0)] == ["resolved"]
    assert hm.active() == []
    assert hm.counts()["firing"] == 1 and hm.counts()["resolved"] == 1


def test_health_for_s_hold_and_rate_window():
    reg = MetricsRegistry()
    reg.gauge("v").set(10.0)
    hm = HealthMonitor(reg, [AlertRule("held", "v", op=">", threshold=1.0,
                                       for_s=2.0)])
    assert hm.evaluate(0.0) == []            # pending, not yet held
    assert hm.evaluate(1.0) == []
    assert [a["rule"] for a in hm.evaluate(2.5)] == ["held"]
    # rate: an empty counter is a zero baseline, so the first increments
    # within the window register as a positive rate
    reg2 = MetricsRegistry()
    c = reg2.counter("evs_total")
    hm2 = HealthMonitor(reg2, [AlertRule("busy", "evs_total", mode="rate",
                                         window_s=2.0, op=">",
                                         threshold=1.0)])
    assert hm2.evaluate(0.0) == []
    c.inc(10)
    assert [a["rule"] for a in hm2.evaluate(1.0)] == ["busy"]  # 10/s
    # window slides: no new increments -> rate decays -> resolves
    assert hm2.evaluate(2.0) == []           # still >1 within window
    assert [a["state"] for a in hm2.evaluate(4.0)] == ["resolved"]


def test_health_ratio_and_quantile_rules():
    reg = MetricsRegistry()
    bad = reg.counter("bad_total")
    tot = reg.counter("all_total")
    h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
    hm = HealthMonitor(reg, [
        AlertRule("burn", "bad_total", mode="ratio",
                  denominator="all_total", op=">", threshold=0.5),
        AlertRule("slow", "lat", mode="p50", op=">", threshold=1.0),
    ])
    assert hm.evaluate(0.0) == []            # zero denominator: skipped
    tot.inc(4)
    bad.inc(3)
    assert [a["rule"] for a in hm.evaluate(1.0)] == ["burn"]
    for v in (0.05, 5.0, 5.0, 5.0):
        h.observe(v)
    new = hm.evaluate(2.0)
    assert [a["rule"] for a in new] == ["slow"]
    assert new[0]["value"] > 1.0


def test_health_trace_instants_and_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.gauge("v").set(2.0)
    tr = TraceRecorder()
    hm = HealthMonitor(reg, [AlertRule("a", "v", op=">", threshold=1.0,
                                       severity="critical")])
    hm.attach_trace(tr, t0=100.0)
    hm.evaluate(1.5)
    reg.gauge("v").set(0.0)
    hm.close(3.0)
    path = tmp_path / "a.alerts.jsonl"
    assert hm.export_jsonl(str(path)) == 2
    rows = [json.loads(x) for x in path.read_text().splitlines()]
    assert [r["state"] for r in rows] == ["firing", "resolved"]
    assert rows[0]["severity"] == "critical" and rows[0]["t"] == 1.5
    # instants land on the absolute clock (t0 + run-relative time) and
    # replay through the perf_report path
    back = alerts_from_events(events_from_recorder(tr))
    assert [a["state"] for a in back] == ["firing", "resolved"]
    assert back[0]["t"] == pytest.approx(101.5)


# ---------------------------------------------------------------------------
# end-to-end: ledgered serving run conserves, reconstructs, stays free


def _ledgered_run(tmp_path, tag, *, obs=False, horizon_s=None):
    eng = M2CacheEngine(paper_model="llama-7b", dram_capacity_gb=6.0,
                        ssd_dir=str(tmp_path / tag))
    kw = {}
    if obs:
        reg = MetricsRegistry()
        kw = dict(trace=TraceRecorder(), metrics=reg,
                  ledger=TimeLedger(), health=HealthMonitor(reg))
    sched = ContinuousBatchScheduler(
        eng, max_batch=2, hbm_kv_gb=2e-4, dram_kv_gb=1e-4,
        prefill_chunk=8, **kw)
    reqs = requests_from_trace(
        [ArrivalEvent(rid=i, arrival_s=0.3 * i, prompt_len=12 + 4 * i,
                      max_new_tokens=4 + i) for i in range(4)])
    return sched, sched.run(reqs, horizon_s=horizon_s)


def test_serving_run_conserves_time_and_carbon(tmp_path):
    sched, rep = _ledgered_run(tmp_path, "led", obs=True, horizon_s=30.0)
    led = sched.ledger
    assert led.check() == []
    res = led.residues()
    assert res["time_residue_frac"] < 1e-9   # exact by construction
    assert res["gco2_residue_frac"] < 1e-9
    assert led.span_s == pytest.approx(rep.modeled_span_s)
    assert led.gco2_total_g == pytest.approx(rep.carbon["oce_g"])
    fam = led.by_family()
    assert fam["trailing_idle"] == pytest.approx(
        30.0 - rep.modeled_span_s)
    assert fam.get("prefill_compute", 0.0) > 0
    assert fam.get("decode_compute", 0.0) > 0
    assert fam.get("weight_stall", 0.0) > 0  # DRAM-resident weights stall


def test_ledger_and_health_never_perturb_modeled_clock(tmp_path):
    _, rep_off = _ledgered_run(tmp_path, "off")
    _, rep_on = _ledgered_run(tmp_path, "on", obs=True)
    assert rep_on.modeled_span_s == rep_off.modeled_span_s
    assert rep_on.decode_steps == rep_off.decode_steps
    assert [r.ttft_s for r in rep_on.requests] == \
        [r.ttft_s for r in rep_off.requests]
    assert rep_on.carbon["oce_g"] == rep_off.carbon["oce_g"]


def test_perf_report_rebuilds_from_trace_alone(tmp_path):
    sched, rep = _ledgered_run(tmp_path, "pr", obs=True)
    path = tmp_path / "run.trace.json"
    sched.trace.export_chrome(str(path))
    out = perf_report.report(str(path), top=3,
                             collapsed=str(tmp_path / "run.collapsed"))
    led = out["ledger"]
    assert led["conserved"]
    assert led["span_s"] == pytest.approx(rep.modeled_span_s)
    assert led["gco2_total_g"] == pytest.approx(rep.carbon["oce_g"])
    assert sum(led["time_by_family_s"].values()) == \
        pytest.approx(led["horizon_s"])
    groups = out["profile"]["dispatch_groups"]
    assert any(k.startswith("prefill/") for k in groups)
    assert any(k.startswith("decode/") for k in groups)
    # dispatch-group cost terms were carried through the trace
    assert sum(g["hbm_read_s"] for g in groups.values()) > 0
    assert sum(g["kernel_launch_s"] for g in groups.values()) > 0
    assert len(out["profile"]["hottest_requests"]) == 3
    assert (tmp_path / "run.collapsed").read_text().strip()
    # cross-check mode agrees with the run's own summary JSON
    summary = {"summary": {"modeled_span_s": rep.modeled_span_s},
               "carbon_g": rep.carbon}
    spath = tmp_path / "out.json"
    spath.write_text(json.dumps(summary, default=float))
    cc = perf_report.cross_check(
        reconstruct(events_from_chrome(
            json.loads(path.read_text()))), str(spath))
    assert cc["ok"]


# ---------------------------------------------------------------------------
# scripts/trace_report.py: the offline reconstruction must match the
# ServingReport (TTFT, tier traffic, carbon) from the trace file alone


@pytest.fixture(scope="module")
def tiny_model():
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.models import transformer as T
    cfg = get_config("qwen2.5-14b", tiny=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32,
                           m2=True)
    return cfg, params


@pytest.mark.slow
def test_trace_report_matches_serving_report(tmp_path, tiny_model):
    import trace_report
    from repro.serving import shared_prefix_trace
    cfg, params = tiny_model
    eng = M2CacheEngine(cfg=cfg, params=params, dram_capacity_gb=0.5,
                        batched_decode=True, prefill_bucket=8, seed=0)
    reg = MetricsRegistry()
    sched = ContinuousBatchScheduler(
        eng, max_batch=4, hbm_kv_gb=1.1e-4, dram_kv_gb=5e-5,
        prefill_chunk=8, prefix_caching=True, trace=TraceRecorder(),
        metrics=reg, ledger=TimeLedger(), health=HealthMonitor(reg))
    events = shared_prefix_trace(6, rate_rps=1e4, num_groups=2,
                                 prefix_len=12, reuse_ratio=0.75, turns=2,
                                 gen_len=(12, 16),
                                 vocab_size=cfg.vocab_size, seed=0)
    rep = sched.run(requests_from_trace(events, vocab_size=cfg.vocab_size,
                                        seed=0))
    path = tmp_path / "run.trace.json"
    sched.trace.export_chrome(str(path))
    out = trace_report.report(str(path))
    # TTFT / latency reconstructed from spans == scheduler's accounting
    timelines = out["requests"]
    assert sorted(timelines) == sorted(r.rid for r in rep.requests)
    for r in rep.requests:
        assert timelines[r.rid]["ttft_s"] == pytest.approx(r.ttft_s,
                                                           abs=1e-9)
        assert timelines[r.rid]["latency_s"] == pytest.approx(
            r.latency_s, abs=1e-9)
    # tier traffic: the kv-instant edges sum back to the cache's own
    # byte counters, edge by direction
    edges = out["tier_transfers"]
    assert rep.preemptions > 0 and edges    # the budgets force paging
    down = sum(g["bytes"] for e, g in edges.items()
               if e in ("hbm->dram", "dram->ssd"))
    up = sum(g["bytes"] for e, g in edges.items()
             if e in ("dram->hbm", "ssd->hbm"))
    assert down == pytest.approx(rep.kv_stats["kv_swap_out_bytes"])
    assert up == pytest.approx(rep.kv_stats["kv_swap_in_bytes"])
    assert edges.get("dram->ssd", {}).get("bytes", 0) == \
        pytest.approx(rep.kv_stats["kv_ssd_write_bytes"])
    assert edges.get("ssd->hbm", {}).get("bytes", 0) == \
        pytest.approx(rep.kv_stats["kv_ssd_read_bytes"])
    # carbon counter track replays the accountant's operational total
    assert out["carbon"]["gco2_total"] == pytest.approx(
        rep.carbon["oce_g"], abs=1e-12)
    # and the run's ledger balanced with a real kv_stall share
    led = sched.ledger
    assert led.check() == []
    assert led.by_family().get("kv_stall", 0.0) > 0


# ---------------------------------------------------------------------------
# SSD quarantine re-probe (bounded backoff, modeled clock)


def _probed_kv(tmp_path, faults, clock, **kw):
    kv = TieredKVCache(
        num_layers=2, d_model=8, hbm_capacity_bytes=1 << 20,
        dram_capacity_bytes=1 << 20, ssd_dir=str(tmp_path / "kv"),
        block_tokens=4, bytes_per_token=256.0, faults=faults,
        ssd_probe_cooldown_s=1.0, ssd_probe_cooldown_max_s=4.0, **kw)
    kv.set_clock(clock)
    return kv


def _trip(kv):
    for _ in range(kv.ssd_breaker_threshold):
        kv._note_ssd_failure()
    assert kv.ssd_quarantined


def test_quarantine_reprobe_backoff_and_rejoin(tmp_path):
    t = [0.0]
    inj = FaultInjector().arm("ssd.write", rate=1.0, until_s=3.5)
    inj.set_clock(lambda: t[0])
    kv = _probed_kv(tmp_path, inj, lambda: t[0])
    tr = TraceRecorder()
    kv.attach_obs(trace=tr, clock=lambda: t[0])
    _trip(kv)
    # inside the cooldown: no probe at all
    t[0] = 0.5
    assert not kv._ssd_usable() and kv.ssd_probes == 0
    # first probe at t=1.0 fails (write fault window) -> cooldown doubles
    t[0] = 1.0
    assert not kv._ssd_usable()
    assert kv.ssd_probes == 1 and kv.ssd_probe_failures == 1
    # still inside the doubled (2s) cooldown: no second probe
    t[0] = 2.5
    assert not kv._ssd_usable() and kv.ssd_probes == 1
    # second probe at t=3.0 fails again -> cooldown doubles to 4s
    t[0] = 3.0
    assert not kv._ssd_usable() and kv.ssd_probe_failures == 2
    # the fault window closed; probe at t=7.0 succeeds -> tier rejoins
    t[0] = 7.0
    assert kv._ssd_usable()
    assert not kv.ssd_quarantined and kv.ssd_rejoins == 1
    names = [e.name for e in tr.events()]
    assert names.count("ssd_probe_failed") == 2
    assert "ssd_rejoin" in names
    s = kv.stats()
    assert s["kv_ssd_probes"] == 3 and s["kv_ssd_rejoins"] == 1
    assert s["kv_ssd_quarantined"] == 0.0


def test_quarantine_without_clock_stays_quarantined(tmp_path):
    """No modeled-clock reader -> no probes: the pre-probe behavior
    (quarantined until restart) is preserved, never an exception."""
    kv = TieredKVCache(
        num_layers=2, d_model=8, hbm_capacity_bytes=1 << 20,
        dram_capacity_bytes=1 << 20, ssd_dir=str(tmp_path / "kv"),
        block_tokens=4, bytes_per_token=256.0)
    _trip(kv)
    assert not kv._ssd_usable()
    assert kv.ssd_probes == 0 and kv.ssd_quarantined


def test_quarantine_cooldown_caps_and_resets(tmp_path):
    t = [0.0]
    inj = FaultInjector().arm("ssd.write", rate=1.0, until_s=100.0)
    inj.set_clock(lambda: t[0])
    kv = _probed_kv(tmp_path, inj, lambda: t[0])
    _trip(kv)
    # drive repeated failed probes: 1 -> 2 -> 4 -> capped at 4
    for _ in range(5):
        t[0] = kv._next_probe_at
        kv._ssd_usable()
    assert kv._probe_cooldown == pytest.approx(4.0)   # capped at max
    # a successful rejoin resets the schedule for the next quarantine
    t[0] = 200.0
    inj2 = FaultInjector()                   # no rules: probes succeed
    kv.attach_faults(inj2)
    t[0] = max(t[0], kv._next_probe_at)
    assert kv._ssd_usable() and kv.ssd_rejoins == 1
    assert kv._probe_cooldown == pytest.approx(1.0)
    assert kv._next_probe_at is None
