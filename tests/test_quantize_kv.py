"""Property tests for the KV payload quantization codec
(``core/quantize.py``): the storage format behind mixed-precision KV
tiers (``serving/kv_cache.py``).

Acceptance properties (hypothesis when installed, deterministic random
sample otherwise — see ``tests/_hypothesis_compat.py``):

* int8 / int4 quant→dequant error is bounded by half the stored scale
  per element (the symmetric-rounding guarantee the divergence gate in
  ``eval/divergence.py`` builds on);
* ``unpack_int4(pack_int4(x))`` is bit-exact for odd *and* even lengths
  on any axis (odd lengths exercise the zero-pad + trim path);
* stored scales are finite and strictly positive for arbitrary finite
  inputs, including all-zero rows (the 1e-8 floor);
* a quantize→dequantize round-trip preserves every key, shape and dtype
  of the payload, across array ranks and dtypes;
* precision only decays through ``kv_requantize_payload`` (int4 asked
  for int8 stays int4; fp16 targets are the identity).
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import quantize as Q

_SHAPES = [(3,), (5,), (2, 7), (4, 8), (2, 1, 4, 2, 32), (1, 13),
           (6, 1), (2, 3, 9)]


def _payload(seed: int, shape, dtype=np.float32, scale_pow: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(shape) * (10.0 ** scale_pow)
    return {"['x'][0]['k']": a.astype(dtype),
            "['x'][0]['v']": (a * -0.5).astype(dtype)}


# ---------------------------------------------------------------------------
# error bounds


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000),
       shape=st.sampled_from(_SHAPES),
       scale_pow=st.integers(-3, 3))
def test_int8_roundtrip_error_within_half_scale(seed, shape, scale_pow):
    pay = _payload(seed, shape, scale_pow=scale_pow)
    q = Q.kv_quantize_payload(pay, "int8")
    deq = Q.kv_dequantize_payload(q)
    for key, orig in pay.items():
        scale = np.asarray(q[key + "::scale"], np.float32)
        rows = orig.reshape(-1, orig.shape[-1])
        err = np.abs(np.asarray(deq[key]).reshape(rows.shape) - rows)
        # symmetric rounding: |x - round(x/s)*s| <= s/2 per element
        assert np.all(err <= scale[:, None] / 2 + 1e-6)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000),
       shape=st.sampled_from(_SHAPES),
       scale_pow=st.integers(-3, 3))
def test_int4_roundtrip_error_within_half_scale(seed, shape, scale_pow):
    pay = _payload(seed, shape, scale_pow=scale_pow)
    q = Q.kv_quantize_payload(pay, "int4")
    deq = Q.kv_dequantize_payload(q)
    G = Q.KV_INT4_GROUP
    for key, orig in pay.items():
        # per-group fp16 scales: the bound is each element's own group
        # scale (computed in fp32 during quantization — allow the fp16
        # storage rounding as relative slack)
        scale = np.asarray(q[key + "::scale"]).astype(np.float32)
        rows, ng = scale.shape
        flat = orig.reshape(rows, -1)
        padded = np.zeros((rows, ng * G), np.float32)
        padded[:, :flat.shape[1]] = flat
        err = np.abs(np.asarray(deq[key], np.float32).reshape(rows, -1)
                     - flat)
        bound = np.repeat(scale, G, axis=1)[:, :flat.shape[1]]
        assert np.all(err <= bound * 0.505 + 1e-6)


# ---------------------------------------------------------------------------
# bit-exact nibble packing


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000),
       rows=st.integers(1, 5), length=st.integers(1, 17),
       axis=st.sampled_from([0, 1, -1]))
def test_pack_unpack_int4_bit_exact(seed, rows, length, axis):
    rng = np.random.default_rng(seed)
    q = rng.integers(-7, 8, size=(rows, length)).astype(np.int8)
    packed = Q.pack_int4(q, axis=axis)
    orig_len = q.shape[axis]
    out = np.asarray(Q.unpack_int4(packed, axis, orig_len=orig_len))
    np.testing.assert_array_equal(out, q)


def test_pack_int4_odd_and_even_last_dim_sizes():
    for length in (4, 7):                      # even + odd
        q = np.arange(-2, length - 2, dtype=np.int8).reshape(1, length)
        packed = Q.pack_int4(q, axis=1)
        assert packed.shape == (1, (length + 1) // 2)
        out = np.asarray(Q.unpack_int4(packed, 1, orig_len=length))
        np.testing.assert_array_equal(out, q)


# ---------------------------------------------------------------------------
# scale sanity


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), shape=st.sampled_from(_SHAPES),
       precision=st.sampled_from(["int8", "int4"]),
       zero=st.booleans())
def test_scales_finite_and_positive(seed, shape, precision, zero):
    pay = _payload(seed, shape)
    if zero:           # all-zero payloads hit the 1e-8 scale floor
        pay = {k: np.zeros_like(v) for k, v in pay.items()}
    q = Q.kv_quantize_payload(pay, precision)
    for key in pay:
        scale = np.asarray(q[key + "::scale"], np.float32)
        assert np.all(np.isfinite(scale))
        assert np.all(scale > 0)


# ---------------------------------------------------------------------------
# structure preservation + precision decay


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), shape=st.sampled_from(_SHAPES),
       dtype=st.sampled_from([np.float32, np.float16]),
       precision=st.sampled_from(["int8", "int4"]))
def test_roundtrip_preserves_keys_shapes_dtypes(seed, shape, dtype,
                                                precision):
    pay = _payload(seed, shape, dtype=dtype)
    q = Q.kv_quantize_payload(pay, precision)
    assert Q.kv_payload_precision(q) == precision
    if shape[-1] >= 16 and dtype is np.float32:
        # compression holds once rows are long enough to amortize the
        # per-row scale/meta overhead (real KV leaves have 32-wide rows)
        assert Q.kv_payload_nbytes(q) < Q.kv_payload_nbytes(pay)
    deq = Q.kv_dequantize_payload(q)
    assert sorted(deq) == sorted(pay)
    for key, orig in pay.items():
        assert deq[key].shape == orig.shape
        assert deq[key].dtype == orig.dtype


def test_requantize_only_decays():
    pay = _payload(0, (4, 32))
    assert Q.kv_requantize_payload(pay, "fp16") is pay
    q8 = Q.kv_requantize_payload(pay, "int8")
    assert Q.kv_payload_precision(q8) == "int8"
    assert Q.kv_requantize_payload(q8, "int8") is q8
    q4 = Q.kv_requantize_payload(q8, "int4")
    assert Q.kv_payload_precision(q4) == "int4"
    # re-widening is refused: int4 stays int4 when asked for int8
    assert Q.kv_requantize_payload(q4, "int8") is q4
    assert Q.kv_requantize_payload(q4, "fp16") is q4


def test_unquantized_payload_passthrough():
    pay = _payload(1, (2, 8))
    assert Q.kv_payload_precision(pay) == "fp16"
    assert Q.kv_dequantize_payload(pay) is pay
    assert Q.kv_dequantize_payload(None) is None
    assert Q.kv_payload_precision(None) == "fp16"
