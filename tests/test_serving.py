"""Serving subsystem: tiered KV cache paging, continuous-batch scheduling,
preemption under KV pressure, step-level engine API, and the
batched-beats-sequential acceptance property."""
import numpy as np
import pytest

from repro.core.engine import M2CacheEngine
from repro.serving import (ContinuousBatchScheduler, RequestState,
                           ServingRequest, TieredKVCache, poisson_trace,
                           requests_from_trace)


def _kv(tmp_path, *, hbm_blocks=2, dram_blocks=2, block_tokens=4,
        bytes_per_token=256.0):
    bb = block_tokens * bytes_per_token
    return TieredKVCache(
        num_layers=2, d_model=8,
        hbm_capacity_bytes=hbm_blocks * bb,
        dram_capacity_bytes=dram_blocks * bb,
        ssd_dir=str(tmp_path / "kv"), block_tokens=block_tokens,
        bytes_per_token=bytes_per_token, max_file_bytes=int(bb))


# ---------------------------------------------------------------------------
# TieredKVCache


def test_kv_alloc_append_and_block_table(tmp_path):
    kv = _kv(tmp_path, hbm_blocks=8)
    kv.alloc(0, 5)                       # 5 tokens, block=4 -> 2 blocks
    assert len(kv.table[0]) == 2
    assert kv.hbm_used == 2 * kv.block_bytes
    for _ in range(3):                   # 5 -> 8 tokens: still 2 blocks
        kv.append_token(0)
    assert len(kv.table[0]) == 2
    kv.append_token(0)                   # 9th token -> 3rd block
    assert len(kv.table[0]) == 3
    kv.free(0)
    assert kv.hbm_used == 0 and not kv.blocks and not kv.table


def test_kv_lru_eviction_pages_to_dram_then_ssd(tmp_path):
    kv = _kv(tmp_path, hbm_blocks=2, dram_blocks=1)
    dt = kv.alloc(0, 8)                  # fills both HBM blocks
    assert dt == 0.0                     # no eviction yet
    dt = kv.alloc(1, 8, protect=[1])     # evicts rid 0's blocks (LRU)
    assert dt > 0.0                      # swap cost charged
    tiers = [kv.blocks[b].tier for b in kv.table[0]]
    # DRAM holds one block, the overflow spilled to flash (real file I/O)
    assert sorted(tiers) == ["dram", "ssd"]
    assert kv.ssd.bytes_written > 0
    # 2 HBM->DRAM demotions + 1 DRAM->SSD spill = 3 block moves out
    assert kv.stats()["kv_swap_out_bytes"] == 3 * kv.block_bytes
    # swap back in: rid 1 gets evicted in turn
    dt = kv.ensure_resident(0, protect=[0])
    assert dt > 0.0
    assert all(kv.blocks[b].tier == "hbm" for b in kv.table[0])
    assert kv.stats()["kv_swap_in_bytes"] == 2 * kv.block_bytes
    assert kv.stats()["kv_ssd_read_bytes"] > 0


def test_kv_ssd_blocks_cleaned_up(tmp_path):
    """Blocks promoted out of flash or freed must not leave files behind."""
    import os
    kv = _kv(tmp_path, hbm_blocks=2, dram_blocks=1)
    kv.alloc(0, 8)
    kv.alloc(1, 8, protect=[1])          # rid 0: one block dram, one ssd
    assert kv.stats()["kv_ssd_blocks"] == 1
    kv.ensure_resident(0, protect=[0])   # promote: flash copy deleted
    n_bins = lambda: sum(f.endswith(".bin")
                         for f in os.listdir(tmp_path / "kv"))
    assert kv.stats()["kv_ssd_blocks"] == 1      # now rid 1 spilled
    kv.free(0)
    kv.free(1)
    assert n_bins() == 0 and not kv.blocks


def test_kv_protected_blocks_survive_pressure(tmp_path):
    kv = _kv(tmp_path, hbm_blocks=2)
    kv.alloc(0, 8, protect=[0])
    kv.alloc(1, 8, protect=[0, 1])       # nothing evictable -> over budget
    assert all(kv.blocks[b].tier == "hbm" for b in kv.table[0])
    assert kv.over_budget()
    assert not kv.can_admit(4, protect=[0, 1])


# ---------------------------------------------------------------------------
# engine step API


def test_prefill_decode_step_advances_clock_and_tokens(tmp_path):
    eng = M2CacheEngine(paper_model="llama-7b", dram_capacity_gb=6.0,
                        ssd_dir=str(tmp_path / "w"))
    c0 = eng.clock
    s1 = eng.prefill(prompt_len=8, rid=0)
    s2 = eng.prefill(prompt_len=8, rid=1)
    assert eng.clock > c0                # prefill charged
    c1 = eng.clock
    rep = eng.decode_step([s1, s2])
    assert rep.batch_size == 2
    assert rep.modeled_s == pytest.approx(eng.clock - c1)
    assert len(s1.tokens) == len(s2.tokens) == 1


def test_decode_step_batch_amortises_weight_stream(tmp_path):
    """B sessions in one step must cost less than B sequential steps."""
    def span(B):
        eng = M2CacheEngine(paper_model="llama-7b", dram_capacity_gb=6.0,
                            ssd_dir=str(tmp_path / f"b{B}"))
        sessions = [eng.prefill(prompt_len=8, rid=r) for r in range(B)]
        c0 = eng.clock
        if B > 1:
            eng.decode_step(sessions)
        else:
            for s in sessions:
                eng.decode_step([s])
        return eng.clock - c0

    # 4 tokens in one batched step vs 4 singleton steps of one session:
    batched = span(4)
    eng = M2CacheEngine(paper_model="llama-7b", dram_capacity_gb=6.0,
                        ssd_dir=str(tmp_path / "seq"))
    sess = [eng.prefill(prompt_len=8, rid=r) for r in range(4)]
    c0 = eng.clock
    for s in sess:
        eng.decode_step([s])
    sequential = eng.clock - c0
    assert batched < sequential


def test_zero_infinity_serving_steps(tmp_path):
    eng = M2CacheEngine(paper_model="llama-7b", mode="zero_infinity",
                        ssd_dir=str(tmp_path / "zi"))
    s = [eng.prefill(prompt_len=4, rid=r) for r in range(2)]
    c0 = eng.clock
    rep = eng.decode_step(s)
    assert rep.modeled_s > 0 and eng.clock == pytest.approx(c0
                                                            + rep.modeled_s)


# ---------------------------------------------------------------------------
# continuous-batch scheduler


def _run(tmp_path, tag, *, max_batch, hbm_kv_gb=1.0, dram_kv_gb=2.0,
         n=8, rate=4.0, seed=0):
    eng = M2CacheEngine(paper_model="llama-7b", dram_capacity_gb=6.0,
                        ssd_dir=str(tmp_path / tag))
    trace = poisson_trace(n, rate, seed=seed, prompt_len=(8, 16),
                          gen_len=(8, 12))
    sched = ContinuousBatchScheduler(eng, max_batch=max_batch,
                                     hbm_kv_gb=hbm_kv_gb,
                                     dram_kv_gb=dram_kv_gb)
    return sched.run(requests_from_trace(trace))


def test_scheduler_completes_all_requests(tmp_path):
    rep = _run(tmp_path, "all", max_batch=4)
    assert len(rep.requests) == 8
    assert all(r.state is RequestState.FINISHED for r in rep.requests)
    assert all(r.generated == r.max_new_tokens for r in rep.requests)
    assert all(r.latency_s > 0 for r in rep.requests)
    assert all(r.ttft_s <= r.latency_s for r in rep.requests)
    # batched: fewer decode steps than total tokens
    assert rep.decode_steps < rep.total_tokens
    assert rep.carbon["total_g"] > 0


def test_continuous_batching_beats_sequential(tmp_path):
    """Acceptance: >= 8 concurrent requests, batched > sequential tok/s."""
    batched = _run(tmp_path, "bat", max_batch=8)
    sequential = _run(tmp_path, "seq", max_batch=1)
    assert batched.tokens_per_s > sequential.tokens_per_s
    # latency improves too (queueing dominates the sequential system)
    assert batched.summary()["p99_latency_s"] < \
        sequential.summary()["p99_latency_s"]
    # per-request carbon drops with the shared weight stream
    assert batched.summary()["gco2_per_request"] < \
        sequential.summary()["gco2_per_request"]


def test_kv_pressure_triggers_preemption_and_swaps(tmp_path):
    rep = _run(tmp_path, "tight", max_batch=8, hbm_kv_gb=0.05,
               dram_kv_gb=0.02, n=10)
    assert len(rep.requests) == 10                 # everyone still finishes
    assert rep.preemptions > 0
    assert rep.kv_stats["kv_preempt_swaps"] > 0
    assert rep.kv_stats["kv_swap_out_bytes"] > 0
    assert rep.kv_stats["kv_swap_in_bytes"] > 0
    # paging costs landed on the modeled clock
    assert rep.kv_stats["kv_swap_s"] > 0
    roomy = _run(tmp_path, "roomy", max_batch=8, n=10)
    assert rep.modeled_span_s > roomy.modeled_span_s


def test_scheduler_real_tiny_mode(tmp_path, key):
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.models import transformer as T
    cfg = get_config("qwen2.5-14b", tiny=True)
    params = T.init_params(key, cfg, dtype=jnp.float32, m2=True)
    eng = M2CacheEngine(cfg=cfg, params=params, dram_capacity_gb=0.5,
                        ssd_dir=str(tmp_path / "real"))
    trace = poisson_trace(3, 100.0, seed=0, prompt_len=(6, 6),
                          gen_len=(3, 4))
    reqs = requests_from_trace(trace, vocab_size=cfg.vocab_size)
    rep = ContinuousBatchScheduler(eng, max_batch=2).run(reqs)
    assert len(rep.requests) == 3
    for r in rep.requests:
        assert len(r.session.tokens) == r.max_new_tokens
        assert all(isinstance(t, int) for t in r.session.tokens)
    assert rep.cache_stats["ssd_bytes_read"] > 0


def test_real_engine_serves_promptless_requests(tmp_path, key):
    """A real-mode engine must fall back to analytic sessions for requests
    without token prompts (mode is per session, not per engine)."""
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.models import transformer as T
    cfg = get_config("qwen2.5-14b", tiny=True)
    params = T.init_params(key, cfg, dtype=jnp.float32, m2=True)
    eng = M2CacheEngine(cfg=cfg, params=params, dram_capacity_gb=0.5,
                        ssd_dir=str(tmp_path / "real"))
    reqs = [ServingRequest(rid=i, prompt_len=6, max_new_tokens=3)
            for i in range(2)]
    rep = ContinuousBatchScheduler(eng, max_batch=2).run(reqs)
    assert len(rep.requests) == 2
    assert all(r.generated == 3 for r in rep.requests)
