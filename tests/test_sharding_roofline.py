"""Sharding policy properties + HLO cost-model validation + a subprocess
multi-device lowering check (the main pytest process keeps its 1-device
backend; the 8-device mesh lives in a child process)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ASSIGNED_ARCHS, get_config
from repro.sharding import MeshAxes, checked_pspec

# HLO-cost comparisons and the 8-device subprocess lowering assume an XLA
# build/device topology this container cannot provide.
from conftest import needs_accelerator


# ---------------------------------------------------------------------------
# checked_pspec properties


@settings(max_examples=50, deadline=None)
@given(dim=st.integers(1, 4096), data=st.sampled_from([1, 2, 4, 16]),
       model=st.sampled_from([1, 4, 16]))
def test_checked_pspec_only_divisible(dim, data, model):
    axes = MeshAxes(pod=1, data=data, model=model)
    spec = checked_pspec(axes, (dim,), "model")
    if spec[0] == "model":
        assert dim % model == 0
    spec2 = checked_pspec(axes, (dim,), ("data", "model"))
    names = spec2[0]
    if names is not None:
        size = np.prod([{"data": data, "model": model}[n]
                        for n in (names if isinstance(names, tuple)
                                  else (names,))])
        assert dim % size == 0


def test_fused_head_dims_divisible_for_all_archs():
    """The sharding design requires (H·Dh) % 16 == 0 for every assigned
    arch — verified here as a config invariant."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if cfg.num_heads:
            assert (cfg.num_heads * cfg.head_dim) % 16 == 0, arch
            assert (cfg.num_kv_heads * cfg.head_dim) % 16 == 0, arch
        assert cfg.d_model % 16 == 0, arch
        if cfg.d_ff:
            assert cfg.d_ff % 16 == 0, arch


def test_exact_assigned_dimensions():
    """Spec table from the assignment — guard against config drift."""
    expect = {
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }
    for arch, (L, d, H, kv, f, V) in expect.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads,
               cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, H, kv, f, V), (arch, got)
    assert get_config("grok-1-314b").num_experts == 8
    assert get_config("grok-1-314b").num_experts_per_tok == 2
    assert get_config("llama4-maverick-400b-a17b").num_experts == 128
    assert get_config("llama4-maverick-400b-a17b").num_experts_per_tok == 1
    assert get_config("mamba2-370m").ssm_state == 128


# ---------------------------------------------------------------------------
# HLO cost model


@needs_accelerator
def test_hlo_cost_matches_xla_without_loops(key):
    from repro.roofline.hlo_cost import analyze
    x = jax.random.normal(key, (32, 64))
    w = jax.random.normal(key, (64, 128))
    compiled = jax.jit(lambda a, b: a @ b).lower(x, w).compile()
    mine = analyze(compiled.as_text())
    assert abs(mine["flops"] - 2 * 32 * 64 * 128) / (2 * 32 * 64 * 128) < 0.01


@needs_accelerator
def test_hlo_cost_weights_scan_trip_count(key):
    from repro.roofline.hlo_cost import analyze
    x = jax.random.normal(key, (32, 64))
    ws = jax.random.normal(key, (16, 64, 64))

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), 0), x, ws)[0]

    compiled = jax.jit(f).lower(x, ws).compile()
    mine = analyze(compiled.as_text())
    expect = 16 * 2 * 32 * 64 * 64
    assert abs(mine["flops"] - expect) / expect < 0.05
    assert mine["unknown_trip_whiles"] == 0


def test_model_flops_for():
    from repro.configs.base import INPUT_SHAPES
    from repro.roofline.analysis import model_flops_for
    cfg = get_config("qwen2.5-14b")
    tr = model_flops_for(cfg, INPUT_SHAPES["train_4k"])
    de = model_flops_for(cfg, INPUT_SHAPES["decode_32k"])
    n = cfg.param_count()
    assert abs(tr - 6 * n * 256 * 4096) / tr < 1e-6
    assert abs(de - 2 * n * 128) / de < 1e-6
    # MoE uses active params
    moe = get_config("grok-1-314b")
    assert moe.active_param_count() < 0.45 * moe.param_count()


def test_param_counts_close_to_nameplate():
    """Total parameter counts should be within ~20% of the model names."""
    # llama4-maverick: the assigned pool shape (48L × 128 dense-MoE layers,
    # d_ff 8192/expert + shared) analytically gives ~790B total — the HF
    # 400B card interleaves dense/MoE layers, a detail the pool spec omits.
    # We implement the assigned shape exactly, so test the analytic value.
    expect_b = {"qwen2.5-14b": 14, "qwen2.5-32b": 32, "command-r-35b": 35,
                "mistral-large-123b": 123, "grok-1-314b": 314,
                "mamba2-370m": 0.37, "recurrentgemma-2b": 2.7,
                "llama4-maverick-400b-a17b": 790}
    for arch, b in expect_b.items():
        n = get_config(arch).param_count() / 1e9
        assert 0.7 * b < n < 1.35 * b, (arch, n)


# ---------------------------------------------------------------------------
# multi-device lowering (subprocess; tiny configs on a 2×4 mesh)

_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax
    from repro.launch.steps import build_case
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    results = {}
    for arch in %s:
        for shape in ["train_4k", "decode_32k"]:
            case = build_case(arch, shape, mesh, tiny=True)
            with mesh:
                jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                                 out_shardings=case.out_shardings,
                                 donate_argnums=case.donate_argnums)
                jitted.lower(*case.args)
            results[f"{arch}|{shape}"] = "ok"
    print(json.dumps(results))
""")


@needs_accelerator
@pytest.mark.slow
def test_multi_device_lowering_subprocess():
    archs = ["qwen2.5-14b", "grok-1-314b", "recurrentgemma-2b",
             "mamba2-370m", "musicgen-large"]
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _CHILD % repr(archs)],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(v == "ok" for v in res.values())
