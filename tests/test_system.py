"""End-to-end behaviour tests: training reduces loss, the serving engine
generates with real cache behaviour, ablation/carbon directionality matches
the paper, checkpoint round-trip, data pipeline contracts."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.engine import M2CacheEngine
from repro.data.pipeline import SyntheticCorpus, batches
from repro.models import transformer as T
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def test_training_reduces_loss(tmp_path):
    cfg = get_config("qwen2.5-14b", tiny=True)
    params, opt_state, hist = train(
        cfg, steps=30, batch_size=4, seq_len=32,
        opt_cfg=AdamWConfig(lr=3e-3, total_steps=30, warmup_steps=3),
        log_every=29)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3, hist
    # checkpoint round-trip
    ck = str(tmp_path / "ck")
    checkpoint.save(ck, params, opt_state, {"arch": cfg.name})
    p2, o2, meta = checkpoint.load(ck, params, opt_state)
    assert meta["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_real_generation_and_cache_stats(tmp_path, key):
    cfg = get_config("qwen2.5-14b", tiny=True)
    params = T.init_params(key, cfg, dtype=jnp.float32, m2=True)
    eng = M2CacheEngine(cfg=cfg, params=params, ssd_dir=str(tmp_path),
                        dram_capacity_gb=0.5)
    prompts = np.asarray(jax.random.randint(key, (1, 8), 0, cfg.vocab_size))
    res = eng.generate(prompts, gen_len=5)
    assert res.tokens.shape == (1, 5)
    assert res.tokens_per_s > 0
    assert 0 < res.cache_stats["hbm_hit_ratio"] <= 1.0
    assert res.cache_stats["ssd_bytes_read"] > 0
    assert res.carbon["total_g"] > 0
    # adjacent-token overlap should make hits common (paper Fig. 6: ~80%)
    assert res.cache_stats["hbm_hit_ratio"] > 0.3


def test_engine_m2_generation_matches_plain_m2_decode(tmp_path, key):
    """The cache layer must not change the engine's numerics: tokens equal
    a direct m2-forward greedy decode."""
    cfg = get_config("qwen2.5-14b", tiny=True)
    params = T.init_params(key, cfg, dtype=jnp.float32, m2=True)
    prompts = jnp.asarray(
        jax.random.randint(key, (1, 8), 0, cfg.vocab_size))
    eng = M2CacheEngine(cfg=cfg, params=params, ssd_dir=str(tmp_path))
    res = eng.generate(np.asarray(prompts), gen_len=4)

    cache = T.init_cache(cfg, 1, max_seq=16, dtype=jnp.float32)
    logits, cache, _ = T.forward(cfg, params, prompts, cache=cache,
                                 mode="prefill", m2=True)
    toks = []
    last = logits[:, -1]
    for _ in range(4):
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        toks.append(int(nxt[0]))
        logits, cache, _ = T.forward(cfg, params, nxt[:, None], cache=cache,
                                     mode="decode", m2=True)
        last = logits[:, 0]
    assert list(res.tokens[0]) == toks


def test_carbon_model_directionality():
    from repro.core import carbon
    e_new = carbon.total_carbon(100.0, device_name="h100",
                                accelerator_util=0.9, dram_gb=64,
                                ssd_active=False)
    e_old = carbon.total_carbon(100.0, device_name="rtx3090",
                                accelerator_util=0.9, dram_gb=64,
                                ssd_active=False)
    assert e_old["total_g"] < e_new["total_g"]       # paper Fig. 1
    lo = carbon.total_carbon(10.0, device_name="rtx3090",
                             accelerator_util=0.2, dram_gb=4,
                             ssd_active=True)
    hi = carbon.total_carbon(10.0, device_name="rtx3090",
                             accelerator_util=1.0, dram_gb=64,
                             ssd_active=True)
    assert lo["total_g"] < hi["total_g"]             # util & DRAM scale OCE
    assert lo["ssd_j"] == 10.0 * 2.0                 # paper: SSD 2 W


def test_data_pipeline_contracts():
    for arch in ("qwen2.5-14b", "musicgen-large", "internvl2-1b"):
        cfg = get_config(arch, tiny=True)
        b = next(batches(cfg, batch_size=2, seq_len=32, num_batches=1))
        if cfg.family == "audio":
            assert b["tokens"].shape[:2] == (2, cfg.num_codebooks)
            assert b["tokens"].shape[-1] + b["prefix"].shape[1] == 32
        elif cfg.num_prefix_embeddings:
            assert b["tokens"].shape[1] + b["prefix"].shape[1] == 32
        else:
            assert b["tokens"].shape == (2, 32)
        assert b["tokens"].max() < cfg.vocab_size


def test_synthetic_corpus_has_structure():
    """Bigram structure => a trained model can beat the unigram entropy;
    here we just check determinism and the transition bias."""
    c = SyntheticCorpus(256, seed=1)
    rng1 = np.random.default_rng(5)
    rng2 = np.random.default_rng(5)
    s1, s2 = c.sample(rng1, 200), c.sample(rng2, 200)
    np.testing.assert_array_equal(s1, s2)
    hits = sum(int(s1[i + 1] in c.successors[s1[i]])
               for i in range(len(s1) - 1))
    assert hits / (len(s1) - 1) > 0.4
